"""CLI over the scenario registry: list / run / sweep.

    python -m repro.experiments list [--json]
    python -m repro.experiments run NAME [--driver sim|fleet|engine]...
                                   [--json PATH] [--events PATH]
                                   [--require-identical]
    python -m repro.experiments sweep NAME [--driver D]
                                   [--axis FIELD=V1,V2,...]...
                                   [--json PATH] [--progress]
                                   [--max-cells N]

``run`` with several ``--driver`` flags replays the SAME scenario through
each driver and prints the ledger diff; ``--require-identical`` exits
nonzero on any drift (the CI calibration smoke).  ``sweep`` runs a
registered grid, or an ad-hoc one built from ``--axis`` overrides on a
base scenario.  ``--json`` writes machine-readable rows that
``scripts/make_experiments_tables.py scenarios`` renders as a table.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.core.metrics import format_summary
from repro.experiments import registry, runner
from repro.experiments.spec import Scenario
from repro.experiments.sweep import Sweep


def _parse_axis(text: str):
    """``field=v1,v2,...`` with JSON-typed values (fallback: string)."""
    if "=" not in text:
        raise argparse.ArgumentTypeError(
            f"--axis wants FIELD=V1,V2,... got {text!r}")
    field, _, raw = text.partition("=")
    values = []
    for tok in raw.split(","):
        try:
            values.append(json.loads(tok))
        except json.JSONDecodeError:
            values.append(tok)
    return field, tuple(values)


def _write_json(path: str, rows) -> None:
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def _row(sc: Scenario, driver: str, summary) -> dict:
    return {"scenario": sc.to_dict(), "driver": driver, "summary": summary}


def _cmd_list(args) -> int:
    if args.json:
        _write_json(args.json, {
            "scenarios": [registry.get(n).to_dict()
                          for n in registry.names()],
            "sweeps": [{"name": n,
                        "cells": len(registry.get_sweep(n)),
                        "driver": registry.get_sweep(n).driver,
                        "description": registry.get_sweep(n).description}
                       for n in registry.sweep_names()],
        })
        return 0
    print("scenarios:")
    for name in registry.names():
        sc = registry.get(name)
        print(f"  {name:24s} [{sc.policy:18s}] {sc.description}")
    print("sweeps:")
    for name in registry.sweep_names():
        sw = registry.get_sweep(name)
        print(f"  {name:24s} [{len(sw):3d} cells, driver={sw.driver}] "
              f"{sw.description}")
    return 0


def _events_path(base: str, driver: str, n_drivers: int) -> str:
    """One log per driver: ``PATH`` as-is for a single driver, else
    ``PATH`` with a ``.{driver}.jsonl`` suffix."""
    if n_drivers == 1:
        return base
    stem = base[:-6] if base.endswith(".jsonl") else base
    return f"{stem}.{driver}.jsonl"


def _cmd_run(args) -> int:
    from repro.core.events import EventLog

    sc = registry.get(args.name)
    drivers = args.driver or ["sim"]
    rows, ledgers, logs = [], {}, {}
    for drv in drivers:
        ev = EventLog() if args.events else None
        led = runner.run(sc, drv, events=ev)
        ledgers[drv] = led
        if ev is not None:
            logs[drv] = ev
            path = _events_path(args.events, drv, len(drivers))
            ev.write_jsonl(path)
            print(f"wrote {len(ev)} events to {path}")
        s = runner.summarize(sc, led)
        rows.append(_row(sc, drv, s))
        print(format_summary(f"{sc.name}[{drv}]", s))
    rc = 0
    if len(drivers) >= 2:
        base = drivers[0]
        for drv in drivers[1:]:
            diff = runner.compare(ledgers[base], ledgers[drv],
                                  events_a=logs.get(base),
                                  events_b=logs.get(drv))
            print(f"compare {base} vs {drv}: {diff}")
            rows.append({"scenario": sc.to_dict(),
                         "compare": [base, drv],
                         "identical": diff.identical,
                         "drift": diff.drift()})
            if args.require_identical and not diff.identical:
                rc = 1
    elif args.require_identical:
        print("--require-identical needs at least two --driver flags",
              file=sys.stderr)
        rc = 2
    if args.json:
        _write_json(args.json, rows)
    return rc


def _cmd_sweep(args) -> int:
    if args.axis:
        base = registry.get(args.name)
        sweep = Sweep(name=f"{args.name}-adhoc", base=base,
                      axes=dict(args.axis))
    else:
        sweep = registry.get_sweep(args.name)
    progress = None
    if args.progress:
        def progress(i, total, sc, s):
            print(f"[{i}/{total}] {sc.name}: "
                  f"cold%={s['cold_start_frequency'] * 100:.2f} "
                  f"idle={s['idle_gb_s']:.1f}GB-s", flush=True)
    rows = []
    try:
        for driver in (args.driver or [None]):
            for sc, s in runner.run_sweep(sweep, driver,
                                          progress=progress,
                                          max_cells=args.max_cells):
                rows.append(_row(sc, driver or sweep.driver, s))
                print(format_summary(
                    f"{sc.name}[{driver or sweep.driver}]", s))
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.json:
        _write_json(args.json, rows)
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="run taxonomy-grid scenarios and sweeps")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="list registered scenarios/sweeps")
    p_list.add_argument("--json", metavar="PATH")

    p_run = sub.add_parser("run", help="run one scenario on 1+ drivers")
    p_run.add_argument("name")
    p_run.add_argument("--azure-csv", metavar="PATH",
                       help="real Azure Functions trace CSV for the "
                            "azure_stress cells (sets $REPRO_AZURE_CSV)")
    p_run.add_argument("--driver", action="append",
                       choices=runner.DRIVERS,
                       help="repeatable; 2+ drivers also prints the diff")
    p_run.add_argument("--json", metavar="PATH")
    p_run.add_argument("--events", metavar="PATH",
                       help="capture the per-invocation event log to PATH "
                            "(per-driver .{driver}.jsonl suffix when 2+ "
                            "drivers); with --require-identical the diff "
                            "also gates on event-sequence identity")
    p_run.add_argument("--require-identical", action="store_true",
                       help="exit 1 unless all drivers' ledgers (and, with "
                            "--events, event streams) match")

    p_sw = sub.add_parser("sweep", help="run a registered or ad-hoc grid")
    p_sw.add_argument("name", help="sweep name (or scenario name w/ --axis)")
    p_sw.add_argument("--driver", action="append", choices=runner.DRIVERS)
    p_sw.add_argument("--axis", action="append", type=_parse_axis,
                      metavar="FIELD=V1,V2,...",
                      help="ad-hoc axis over a base *scenario*; repeatable")
    p_sw.add_argument("--json", metavar="PATH")
    p_sw.add_argument("--progress", action="store_true",
                      help="print a [i/N] line as each cell finishes")
    p_sw.add_argument("--max-cells", type=int, default=256, metavar="N",
                      help="refuse grids larger than N cells instead of "
                           "silently running them (default 256)")
    p_sw.add_argument("--azure-csv", metavar="PATH",
                      help="real Azure Functions trace CSV for the "
                           "azure_stress cells (sets $REPRO_AZURE_CSV)")

    args = ap.parse_args(argv)
    if getattr(args, "azure_csv", None):
        import os

        from repro.core.workload import AZURE_CSV_ENV
        os.environ[AZURE_CSV_ENV] = args.azure_csv
    try:
        return {"list": _cmd_list, "run": _cmd_run,
                "sweep": _cmd_sweep}[args.cmd](args)
    except registry.UnknownScenarioError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
