"""Jit-ready kernel entry points.

Each op has three execution paths:

* ``impl="reference"`` — memory-bounded pure-jnp implementation (chunked
  online-softmax flash attention, two-level SSM scan).  This is the path the
  multi-pod dry-run lowers (it is GSPMD-shardable and never materialises an
  O(S^2) score tensor), and what runs in CPU tests/benchmarks.
* ``impl="pallas"`` — the TPU Pallas kernels (``flash_attention.py``,
  ``decode_attention.py``, ``ssm_scan.py``) with explicit BlockSpec VMEM
  tiling; validated on CPU via ``interpret=True``.
* ``impl="oracle"`` — the naive oracles in ``ref.py`` (tests only).

All paths agree to numerical tolerance; see ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# flash attention (training / prefill hot spot)
# --------------------------------------------------------------------------- #


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (halving would degrade to
    chunk=4 for whisper's 1500-frame encoder: 375x375 blocks)."""
    c = min(target, s)
    while c > 1 and s % c:
        c -= 1
    return max(c, 1)


def _flash_reference(q, k, v, *, causal, window, q_pos, kv_pos,
                     q_chunk=1024, kv_chunk=1024):
    """Chunked online-softmax attention in pure jnp (fp32 accumulators)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qc = _pick_chunk(sq, q_chunk)
    kc = _pick_chunk(skv, kv_chunk)
    scale = 1.0 / (d ** 0.5)

    # (B, Skv, Hkv, D) -> (nk, B, kc, Hkv, D)
    kb = jnp.moveaxis(k.reshape(b, skv // kc, kc, hkv, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, skv // kc, kc, hkv, d), 1, 0)
    kpb = kv_pos.reshape(skv // kc, kc)

    def q_block(args):
        qi, qp = args                          # (B, qc, Hkv, G, D), (qc,)
        qi = qi.astype(jnp.float32) * scale

        def kv_step(carry, xs):
            acc, m, l = carry
            kj, vj, kp = xs                    # (B, kc, Hkv, D) x2, (kc,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj.astype(jnp.float32))
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= kp[None, :] > (qp[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, hkv, g, qc, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, Hkv, G, qc, D) -> (B, qc, Hkv, G, D)
        return jnp.moveaxis(out, 3, 1)

    qg = q.reshape(b, sq // qc, qc, hkv, g, d)
    qg = jnp.moveaxis(qg, 1, 0)                      # (nq, B, qc, Hkv, G, D)
    qpb = q_pos.reshape(sq // qc, qc)
    out = jax.lax.map(q_block, (qg, qpb))            # (nq, B, qc, Hkv, G, D)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_pos=None, kv_pos=None, impl: str = "reference"):
    """Blocked attention. q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(sq) + (skv - sq)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    if impl == "oracle":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        q_pos=q_pos, kv_pos=kv_pos)
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_pos=q_pos, kv_pos=kv_pos)
    return _flash_reference(q, k, v, causal=causal, window=window,
                            q_pos=q_pos, kv_pos=kv_pos)


# --------------------------------------------------------------------------- #
# decode attention (single new token vs long KV cache)
# --------------------------------------------------------------------------- #


def decode_attention(q, k_cache, v_cache, valid_mask, *, impl: str = "reference"):
    """q: (B,Hq,D); caches (B,S,Hkv,D); valid_mask (B,S) -> (B,Hq,D)."""
    if impl == "pallas":
        from repro.kernels.decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, valid_mask)
    if impl == "oracle":
        return _ref.decode_attention_ref(q, k_cache, v_cache, valid_mask)
    # memory-light jnp: scores are only (B, Hq, S)
    b, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) / (d ** 0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


# --------------------------------------------------------------------------- #
# selective-scan (Mamba) — chunked two-level scan
# --------------------------------------------------------------------------- #


def ssm_scan(u, delta, A, B, C, D, h0, *, chunk: int = 256,
             impl: str = "reference"):
    """Mamba-1 selective scan.  See ``ref.ssm_scan_ref`` for semantics."""
    if impl == "oracle":
        return _ref.ssm_scan_ref(u, delta, A, B, C, D, h0)
    if impl == "pallas":
        from repro.kernels.ssm_scan import ssm_scan_pallas
        return ssm_scan_pallas(u, delta, A, B, C, D, h0)
    bsz, t, din = u.shape
    n = A.shape[1]
    c = _pick_chunk(t, chunk)

    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def inner_step(h, xs):
        u_t, d_t, b_t, c_t = xs
        decay = jnp.exp(d_t[..., None] * Af[None])
        h = decay * h + (d_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    def chunk_step(h, xs):
        uc, dc, bc, cc = xs                     # (c, Bt, ...) time-major
        h, ys = jax.lax.scan(inner_step, h, (uc, dc, bc, cc))
        return h, ys

    def tm(x):                                   # (Bt, T, ...) -> (nc, c, Bt, ...)
        x = jnp.moveaxis(x, 1, 0)                # (T, Bt, ...)
        return x.reshape(t // c, c, *x.shape[1:])

    hT, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                          (tm(uf), tm(df), tm(Bf), tm(Cf)))
    ys = ys.reshape(t, bsz, din)
    y = jnp.moveaxis(ys, 0, 1) + uf * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype), hT


def ssm_step(u, delta, A, B, C, D, h):
    """Single decode step of the selective scan (B, Din) inputs."""
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    decay = jnp.exp(df[..., None] * A.astype(jnp.float32)[None])
    h = decay * h + (df * uf)[..., None] * B.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32))
    y = y + uf * D.astype(jnp.float32)[None]
    return y.astype(u.dtype), h
