"""Blocked flash-attention Pallas kernel (TPU target, prefill/train hot spot).

TPU adaptation notes (vs the canonical CUDA flash kernel):
  * tiles live in VMEM via explicit ``BlockSpec``s — (block_q, head_dim) and
    (block_k, head_dim) tiles sized so q/k/v/acc fit the ~16 MiB VMEM budget
    with MXU-aligned (multiple-of-128) matmul dims;
  * the KV loop is the innermost *grid* dimension (TPU grids execute
    sequentially per core), with the online-softmax state (m, l, acc) carried
    in VMEM scratch across grid steps — no warp shuffles / shared-memory
    reductions, the MXU consumes (block_q × d) × (d × block_k) tiles directly;
  * GQA is expressed in the index_map: the kv-head index is ``h // group``,
    so kv tiles are fetched once per q-head group rather than materialising
    repeated heads in HBM.

Validated against ``ref.flash_attention_ref`` in interpret mode on CPU
(tests/test_kernels.py sweeps shapes, dtypes, causal/window settings).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attn_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, causal: bool,
                 window: Optional[int], num_kv_blocks: int, scale: float):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = q @ k.T                                             # (bq, bk) on MXU

    qp = qpos_ref[...]                                       # (bq,)
    kp = kpos_ref[...]                                       # (bk,)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > (qp[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           q_pos=None, kv_pos=None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "pad sequences to block multiples"
    if q_pos is None:
        q_pos = jnp.arange(sq) + (skv - sq)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    q_pos = q_pos.astype(jnp.int32)
    kv_pos = kv_pos.astype(jnp.int32)
    nq, nk = sq // bq, skv // bk
    grid = (b, hq, nq, nk)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, num_kv_blocks=nk,
        scale=1.0 / (d ** 0.5))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda bi, h, qi, ki: (qi,)),        # q_pos
            pl.BlockSpec((bk,), lambda bi, h, qi, ki: (ki,)),        # kv_pos
            pl.BlockSpec((1, bq, 1, d), lambda bi, h, qi, ki: (bi, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, qi, ki: (bi, ki, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, h, qi, ki: (bi, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda bi, h, qi, ki: (bi, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m (running max)
            pltpu.VMEM((bq,), jnp.float32),       # l (running denom)
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)
