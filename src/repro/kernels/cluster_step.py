"""Pallas kernel for the batch cold-start simulator's per-step hot loop.

One grid program advances ONE scenario cell through a ``chunk`` of fixed-dt
timesteps: the cohort state (``nw`` container counts, ``fs`` per-function
scalars, ``free`` worker capacity) lives in VMEM scratch across the
sequential chunk axis, so a whole simulation streams only the per-chunk
arrival tile from HBM.  The cell axis is parallel — a 64-cell ``Sweep``
grid is 64 independent programs.

The step itself — TTL-expiry walk down the demotion schedule, warm-hit
serving with tier promotes, first-fit spawn placement, per-tier idle
billing — is implemented here in kernel style (iota one-hots, per-worker
cumsum placement) and tested for parity against the pure-jnp oracle
``repro.kernels.ref.cluster_step_ref`` under ``interpret=True``
(tests/test_batchsim.py).  Layout constants (FS_*/FP_*/SC_*/AG_* columns)
are shared from ``kernels/ref.py``.

Shapes are cold-start sized (F functions x W workers, both small), far
from the fp32 (8, 128) TPU tile — fine in interpret mode (CPU CI) and
acceptable-but-padded when compiled; the CPU production path in
``repro.core.batchsim`` uses the jitted oracle directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import (AG_COLD, AG_DEMOTIONS, AG_EXEC_GB_S,
                               AG_IDLE_PAUSED, AG_IDLE_SNAP, AG_IDLE_WARM,
                               AG_LAT_SUM, AG_LAUNCHED, AG_N, AG_PROMOTIONS,
                               AG_QWAIT_SUM, AG_REQUESTS, AG_WARM, BIG_TIME,
                               FP_EXEC_GB, FP_EXEC_S, FP_MEM_GB, FP_MEM_MB,
                               FP_SVC, FS_DEADLINE, FS_EDGE, FS_HAS_SNAP,
                               FS_IMG, FS_N, FS_QUEUED, FS_TIER, N_TIERS,
                               SC_DT, SC_HORIZON, SC_IMG_CACHE, SC_N,
                               SC_SANITIZE_S, SC_SNAPSHOT, T_DEAD, T_IMG,
                               T_PAUSED, T_SNAP, T_WARM)

DEFAULT_CHUNK = 128


def _pick(table, idx):
    """Row-wise gather ``table[f, idx[f]]`` as a one-hot contraction."""
    k = table.shape[1]
    onehot = (idx[:, None] == jnp.arange(k, dtype=jnp.float32)[None, :])
    return (table * onehot).sum(axis=1)


def _frac_at(frac, tiers):
    """Footprint fraction of each function's tier ([F] from frac [5])."""
    onehot = (tiers[:, None]
              == jnp.arange(N_TIERS, dtype=jnp.float32)[None, :])
    return (frac[None, :] * onehot).sum(axis=1)


def _kernel_step(nw, fs, free, arrivals, conc, now, fparam, promote, dwell,
                 ntier, frac, scal, n_edges):
    """One fixed-dt cohort step (kernel-style implementation; semantics
    documented on ``ref.cluster_step_ref`` and in docs/batchsim.md)."""
    f32 = jnp.float32
    dt = scal[SC_DT]
    dt_eff = jnp.clip(scal[SC_HORIZON] - now, 0.0, dt)
    active = (dt_eff > 0.0).astype(f32)

    tier, edge, deadline = fs[:, FS_TIER], fs[:, FS_EDGE], fs[:, FS_DEADLINE]
    queued, has_snap, img = fs[:, FS_QUEUED], fs[:, FS_HAS_SNAP], fs[:, FS_IMG]
    mem = fparam[:, FP_MEM_MB]
    exec_s = fparam[:, FP_EXEC_S]
    exec_gb = fparam[:, FP_EXEC_GB]
    svc = fparam[:, FP_SVC]
    mem_gb = fparam[:, FP_MEM_GB]
    agg = jnp.zeros((AG_N,), f32)

    # 1. expiry walk — up to n_edges schedule edges can fire per step
    for _ in range(n_edges):
        n = nw.sum(axis=1)
        tgt = _pick(ntier, jnp.clip(edge, 0, n_edges - 1))
        fire = ((n > 0) & (deadline <= now)).astype(f32) * active
        died = fire * (tgt == T_DEAD)
        demoted = fire - died
        new_res = mem * _frac_at(frac, tgt) * (1.0 - died)
        delta_mb = (new_res - mem * _frac_at(frac, tier)) * fire
        free = free - (nw * delta_mb[:, None]).sum(axis=0)
        agg = agg.at[AG_DEMOTIONS].add((demoted * n).sum())
        nw = nw * (1.0 - died)[:, None]
        nxt = _pick(dwell, jnp.clip(edge + 1, 0, n_edges - 1))
        deadline = jnp.where(demoted > 0, now + nxt,
                             jnp.where(died > 0, BIG_TIME, deadline))
        tier = jnp.where(demoted > 0, tgt, tier)
        has_snap = jnp.maximum(has_snap, demoted * (tgt == T_SNAP))
        edge = edge + fire

    # 2. spawn to cover within-step concurrency: the host-precomputed
    # peak overlap ``conc`` (exact from event timestamps) or the
    # Little's-law floor demand*exec_s/dt, whichever is larger
    demand = queued + arrivals
    n = nw.sum(axis=1)
    required = jnp.maximum(
        jnp.ceil(demand * exec_s / jnp.maximum(dt_eff, 1e-9)), conc)
    spawn_want = jnp.clip(required - n, 0.0, demand)
    spawn_tier = jnp.where(
        has_snap > 0, T_SNAP,
        jnp.where((scal[SC_IMG_CACHE] > 0) & (img > 0), T_IMG, T_DEAD))
    spawn_cost = _pick(promote, spawn_tier)

    # vectorized first-fit (see ref.cluster_step_ref): parallel packing
    # against the current free vector, proportional scale-back on any
    # over-committed worker
    need = (spawn_want * active)[:, None]
    cap_w = jnp.maximum(jnp.floor(free[None, :]
                                  / jnp.maximum(mem, 1.0)[:, None]), 0.0)
    take = jnp.clip(need - (jnp.cumsum(cap_w, axis=1) - cap_w), 0.0, cap_w)
    used_w = (take * mem[:, None]).sum(axis=0)
    scale = jnp.where(used_w > free,
                      free / jnp.maximum(used_w, 1e-9), 1.0)
    take = take * scale[None, :]
    nw_pre = nw
    free = free - (take * mem[:, None]).sum(axis=0)
    nw = nw + take
    granted = take.sum(axis=1)
    has_snap = jnp.maximum(has_snap, (granted > 0) * scal[SC_SNAPSHOT])
    img = jnp.maximum(img, (granted > 0).astype(f32))

    # 3. serve queued + fresh demand
    capacity = jnp.floor((n + granted) * svc
                         * jnp.where(dt > 0, dt_eff / dt, 0.0))
    served = jnp.minimum(demand, capacity)
    cohort_demoted = (tier < T_WARM) & (n > 0)
    # promote only the concurrency the step needs; surplus demoted
    # containers retire instead of re-arming (see ref.cluster_step_ref)
    used = jnp.clip(
        jnp.maximum(jnp.ceil(served * exec_s / jnp.maximum(dt_eff, 1e-9)),
                    conc), 1.0, jnp.maximum(n, 1.0))
    promoted_req = jnp.where(cohort_demoted, jnp.minimum(served, used), 0.0)
    cold_spawn = jnp.minimum(granted, served - promoted_req)
    warm_served = served - promoted_req - cold_spawn
    prom_cost = _pick(promote, tier)
    restore = cohort_demoted & (served > 0)
    res_now = mem * _frac_at(frac, tier)
    # warm-cohort surplus retires exponentially at dt/warm_dwell — the
    # per-container TTL clocks the shared deadline can't express (see
    # ref.cluster_step_ref)
    decaying = (~cohort_demoted) & (served > 0) & (n > 0)
    surplus = jnp.clip(n - used, 0.0, None)
    decay = surplus * jnp.minimum(dt_eff / jnp.maximum(dwell[:, 0], 1e-9),
                                  1.0)
    keep = jnp.where(
        restore & (n > 0), used / jnp.maximum(n, 1.0),
        jnp.where(decaying, 1.0 - decay / jnp.maximum(n, 1.0), 1.0))
    delta = jnp.where(restore, keep * (mem - res_now), 0.0) \
        - (1.0 - keep) * res_now
    free = free - (nw_pre * delta[:, None]).sum(axis=0)
    nw = nw - nw_pre * (1.0 - keep)[:, None]
    tier = jnp.where(restore, T_WARM, tier)
    agg = agg.at[AG_PROMOTIONS].add(promoted_req.sum())

    leftover = demand - served
    sanitize = scal[SC_SANITIZE_S]
    busy_warm = warm_served * (exec_s + sanitize)
    busy_cold = promoted_req * (exec_s + prom_cost) \
        + cold_spawn * (exec_s + spawn_cost)
    agg = agg.at[AG_REQUESTS].add(served.sum())
    agg = agg.at[AG_COLD].add((promoted_req + cold_spawn).sum())
    agg = agg.at[AG_WARM].add(warm_served.sum())
    agg = agg.at[AG_LAUNCHED].add(granted.sum())
    agg = agg.at[AG_LAT_SUM].add((busy_warm + busy_cold).sum()
                                 + leftover.sum() * dt_eff)
    agg = agg.at[AG_QWAIT_SUM].add(leftover.sum() * dt_eff)
    agg = agg.at[AG_EXEC_GB_S].add(
        ((busy_warm + (promoted_req + cold_spawn) * exec_s) * exec_gb).sum())

    hit = (served + granted) > 0
    edge = jnp.where(hit, 0.0, edge)
    deadline = jnp.where(hit, now + exec_s + dwell[:, 0], deadline)
    tier = jnp.where(hit, T_WARM, tier)

    # 4. idle GB-s at the cohort tier's footprint
    idle_cs = jnp.clip(nw.sum(axis=1) * dt_eff - busy_warm - busy_cold,
                       0.0, None)
    idle_gb = idle_cs * mem_gb * _frac_at(frac, tier)
    agg = agg.at[AG_IDLE_WARM].add((idle_gb * (tier == T_WARM)).sum())
    agg = agg.at[AG_IDLE_PAUSED].add((idle_gb * (tier == T_PAUSED)).sum())
    agg = agg.at[AG_IDLE_SNAP].add((idle_gb * (tier == T_SNAP)).sum())

    fs = jnp.stack([tier, edge, deadline, leftover, has_snap, img], axis=1)
    return nw, fs, free, agg


def _cluster_kernel(nw_ref, fs_ref, free_ref, arr_ref, conc_ref, fparam_ref,
                    promote_ref, dwell_ref, ntier_ref, frac_ref, scal_ref,
                    nw_out, fs_out, free_out, agg_out,
                    nw_s, fs_s, free_s, agg_s, *,
                    chunk: int, num_chunks: int, n_edges: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        nw_s[...] = nw_ref[0]
        fs_s[...] = fs_ref[0]
        free_s[...] = free_ref[0]
        agg_s[...] = jnp.zeros_like(agg_s)

    arr = arr_ref[0]                                 # (chunk, F)
    conc = conc_ref[0]                               # (chunk, F)
    scal = scal_ref[0]
    dt = scal[SC_DT]
    tables = (fparam_ref[0], promote_ref[0], dwell_ref[0], ntier_ref[0],
              frac_ref[0], scal)

    def body(t, carry):
        nw, fs, free, agg = carry
        now = (ci * chunk + t).astype(jnp.float32) * dt
        nw, fs, free, d = _kernel_step(nw, fs, free, arr[t], conc[t], now,
                                       *tables, n_edges)
        return nw, fs, free, agg + d

    nw, fs, free, agg = jax.lax.fori_loop(
        0, chunk, body, (nw_s[...], fs_s[...], free_s[...], agg_s[...]))
    nw_s[...] = nw
    fs_s[...] = fs
    free_s[...] = free
    agg_s[...] = agg

    @pl.when(ci == num_chunks - 1)
    def _finish():
        nw_out[0] = nw_s[...]
        fs_out[0] = fs_s[...]
        free_out[0] = free_s[...]
        agg_out[0] = agg_s[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def cluster_sim_pallas(nw, fs, free, arrivals, conc, fparam, promote, dwell,
                       ntier, frac, scal, *, chunk: int = DEFAULT_CHUNK,
                       interpret: bool = True):
    """Advance every cell through all T steps in one kernel launch.

    nw: (C, F, W); fs: (C, F, FS_N); free: (C, W); arrivals and conc
    (per-step peak concurrency): (C, T, F); fparam/promote: (C, F, 5);
    dwell/ntier: (C, F, K); frac: (C, 5); scal: (C, SC_N).  T must be a
    multiple of ``chunk`` (the driver pads arrivals with empty steps —
    post-horizon steps are no-ops).

    Returns ``(nw_final, fs_final, free_final, agg)`` with agg (C, AG_N).
    """
    c, t, f = arrivals.shape
    w = nw.shape[2]
    k = dwell.shape[2]
    ck = min(chunk, t)
    assert t % ck == 0, f"T={t} not a multiple of chunk={ck}"
    nc = t // ck

    kernel = functools.partial(_cluster_kernel, chunk=ck, num_chunks=nc,
                               n_edges=k)
    cell = lambda c_, ci: (c_, 0, 0)         # per-cell block, chunk-invariant
    cell2 = lambda c_, ci: (c_, 0)
    return pl.pallas_call(
        kernel,
        grid=(c, nc),
        in_specs=[
            pl.BlockSpec((1, f, w), cell),                        # nw
            pl.BlockSpec((1, f, FS_N), cell),                     # fs
            pl.BlockSpec((1, w), cell2),                          # free
            pl.BlockSpec((1, ck, f), lambda c_, ci: (c_, ci, 0)),  # arrivals
            pl.BlockSpec((1, ck, f), lambda c_, ci: (c_, ci, 0)),  # conc
            pl.BlockSpec((1, f, 5), cell),                        # fparam
            pl.BlockSpec((1, f, N_TIERS), cell),                  # promote
            pl.BlockSpec((1, f, k), cell),                        # dwell
            pl.BlockSpec((1, f, k), cell),                        # ntier
            pl.BlockSpec((1, N_TIERS), cell2),                    # frac
            pl.BlockSpec((1, SC_N), cell2),                       # scal
        ],
        out_specs=[
            pl.BlockSpec((1, f, w), cell),
            pl.BlockSpec((1, f, FS_N), cell),
            pl.BlockSpec((1, w), cell2),
            pl.BlockSpec((1, AG_N), cell2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, f, w), jnp.float32),
            jax.ShapeDtypeStruct((c, f, FS_N), jnp.float32),
            jax.ShapeDtypeStruct((c, w), jnp.float32),
            jax.ShapeDtypeStruct((c, AG_N), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((f, w), jnp.float32),
            pltpu.VMEM((f, FS_N), jnp.float32),
            pltpu.VMEM((w,), jnp.float32),
            pltpu.VMEM((AG_N,), jnp.float32),
        ],
        interpret=interpret,
    )(nw, fs, free, arrivals, conc, fparam, promote, dwell, ntier, frac,
      scal)
