"""Chunked selective-scan (Mamba-1) Pallas kernel — the SSM/hybrid hot spot.

TPU adaptation: the recurrence h_t = exp(Δt·A)·h_{t-1} + Δt·B_t·u_t is
sequential in t but *independent per channel*, so the kernel tiles the
channel dimension (``block_d``) across a parallel grid axis and streams time
in ``chunk``-sized VMEM tiles along the innermost sequential grid axis; the
fp32 state h (block_d, N) persists in VMEM scratch across chunk steps.
Inside a chunk the timestep loop is a ``fori_loop`` over VPU elementwise ops
on (block_d, N) tiles — the TPU replacement for the CUDA kernel's
warp-parallel scan (there is no cross-lane shuffle; the lane dimension IS
the channel tile).

Layout: channel-minor (..., chunk, block_d) tiles keep the 128-wide lane
dimension on channels, which is the natural VREG mapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 256
DEFAULT_BLOCK_D = 256


def _ssm_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, dsk_ref, h0_ref,
                y_ref, hT_ref, h_scr, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)          # (bd, N)

    u = u_ref[0].astype(jnp.float32)                        # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)                      # (chunk, bd)
    a = a_ref[...].astype(jnp.float32)                      # (bd, N)
    bmat = b_ref[0].astype(jnp.float32)                     # (chunk, N)
    cmat = c_ref[0].astype(jnp.float32)                     # (chunk, N)

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * a)                 # (bd, N)
        h = decay * h + (dt[t] * u[t])[:, None] * bmat[t][None, :]
        y_t = (h * cmat[t][None, :]).sum(axis=-1)           # (bd,)
        ys = jax.lax.dynamic_update_slice(ys, y_t[None, :], (t, 0))
        return h, ys

    ys0 = jnp.zeros((chunk, u.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = (ys + u * dsk_ref[...][None, :]).astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _finish():
        hT_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan_pallas(u, delta, A, B, C, D, h0, *, chunk: int = DEFAULT_CHUNK,
                    block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """See ``ref.ssm_scan_ref``.  u/delta: (Bt, T, Din); B/C: (Bt, T, N)."""
    bt, t, din = u.shape
    n = A.shape[1]
    ck = min(chunk, t)
    bd = min(block_d, din)
    assert t % ck == 0 and din % bd == 0
    nc, nd = t // ck, din // bd

    kernel = functools.partial(_ssm_kernel, chunk=ck, num_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(bt, nd, nc),
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda bi, di, ci: (bi, ci, di)),  # u
            pl.BlockSpec((1, ck, bd), lambda bi, di, ci: (bi, ci, di)),  # dt
            pl.BlockSpec((bd, n), lambda bi, di, ci: (di, 0)),           # A
            pl.BlockSpec((1, ck, n), lambda bi, di, ci: (bi, ci, 0)),    # B
            pl.BlockSpec((1, ck, n), lambda bi, di, ci: (bi, ci, 0)),    # C
            pl.BlockSpec((bd,), lambda bi, di, ci: (di,)),               # D skip
            pl.BlockSpec((1, bd, n), lambda bi, di, ci: (bi, di, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, bd, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, t, din), u.dtype),
            jax.ShapeDtypeStruct((bt, din, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u, delta, A, B, C, D, h0)
    return y, hT
