"""Pure-jnp oracles for every Pallas kernel.

These are deliberately naive (O(S^2) score materialisation, step-by-step
scans): they are the *correctness* reference that both the memory-bounded
jnp implementations in ``ops.py`` and the Pallas TPU kernels are tested
against (``tests/test_kernels.py`` sweeps shapes/dtypes with
``assert_allclose``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, num_q_heads):
    """(B, S, Hkv, D) -> (B, S, Hq, D) by repeating kv heads."""
    b, s, hkv, d = k.shape
    rep = num_q_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def attention_mask(q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """(Sq, Skv) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_pos=None, kv_pos=None):
    """Naive attention oracle.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    Returns (B, Sq, Hq, D) in q.dtype; softmax in fp32.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(sq) + (skv - sq)  # suffix alignment (prefill default)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    mask = attention_mask(q_pos, kv_pos, causal=causal, window=window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid_mask):
    """Single-token decode oracle.

    q: (B, Hq, D); caches: (B, S, Hkv, D); valid_mask: (B, S) bool.
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    k = _gqa_expand(k_cache, hq)
    v = _gqa_expand(v_cache, hq)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(valid_mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssm_scan_ref(u, delta, A, B, C, D, h0):
    """Mamba-1 selective-scan oracle (sequential over time, fp32 state).

    u, delta: (Batch, T, Din); A: (Din, N); B, C: (Batch, T, N); D: (Din,);
    h0: (Batch, Din, N).  Returns (y (Batch, T, Din), hT).
    Discretisation: h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * u_t.
    """
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs           # (Bt, Din), (Bt, Din), (Bt, N), (Bt, N)
        decay = jnp.exp(d_t[..., None] * Af[None])          # (Bt, Din, N)
        h = decay * h + (d_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(df, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + uf * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype), hT
