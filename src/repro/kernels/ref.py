"""Pure-jnp oracles for every Pallas kernel.

These are deliberately naive (O(S^2) score materialisation, step-by-step
scans): they are the *correctness* reference that both the memory-bounded
jnp implementations in ``ops.py`` and the Pallas TPU kernels are tested
against (``tests/test_kernels.py`` sweeps shapes/dtypes with
``assert_allclose``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, num_q_heads):
    """(B, S, Hkv, D) -> (B, S, Hq, D) by repeating kv heads."""
    b, s, hkv, d = k.shape
    rep = num_q_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def attention_mask(q_pos, kv_pos, *, causal: bool, window: Optional[int]):
    """(Sq, Skv) boolean mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    return m


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_pos=None, kv_pos=None):
    """Naive attention oracle.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    Returns (B, Sq, Hq, D) in q.dtype; softmax in fp32.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(sq) + (skv - sq)  # suffix alignment (prefill default)
    if kv_pos is None:
        kv_pos = jnp.arange(skv)
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    mask = attention_mask(q_pos, kv_pos, causal=causal, window=window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, valid_mask):
    """Single-token decode oracle.

    q: (B, Hq, D); caches: (B, S, Hkv, D); valid_mask: (B, S) bool.
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    k = _gqa_expand(k_cache, hq)
    v = _gqa_expand(v_cache, hq)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d).astype(jnp.float32)
    scores = jnp.where(valid_mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# batched cold-start cluster step (the batch simulator's physics)
# --------------------------------------------------------------------------- #
# Array-form mirror of one ``ClusterState`` cell for the fixed-timestep
# batch driver (``repro.core.batchsim``).  Containers of one function are
# collapsed into a *cohort*: one count per (function, worker), one warmth
# tier / schedule edge / demotion deadline per function.  All layout
# constants live here so the Pallas kernel (``kernels/cluster_step.py``),
# the table builder, and the tests agree on column meanings.
#
# state (per cell, float32 throughout — tiers/edges are small exact ints):
#   nw   [F, W]   resident containers of function f on worker w
#   fs   [F, 6]   per-function cohort scalars (FS_* columns)
#   free [W]      free memory per worker, MB
# static tables (per cell):
#   fparam  [F, 5]   FP_* columns (mem MB, exec s, GB billed per
#                    execution-second, requests servable per container
#                    per dt, mem GB)
#   promote [F, 5]   seconds to bring a container to serving from tier t
#   dwell   [F, K]   demotion-schedule dwell seconds (inf-padded)
#   ntier   [F, K]   demotion-schedule target tier (DEAD-padded)
#   frac    [5]      resident-footprint fraction per tier
#   scal    [SC_N]   cell scalars (SC_* columns)
# aggregates (one [AG_N] vector per cell, summed over steps):
#   counts + QoS sums that reconstruct into a ledger summary

FS_TIER, FS_EDGE, FS_DEADLINE, FS_QUEUED, FS_HAS_SNAP, FS_IMG = range(6)
FS_N = 6
FP_MEM_MB, FP_EXEC_S, FP_EXEC_GB, FP_SVC, FP_MEM_GB = range(5)
FP_N = 5
SC_DT, SC_HORIZON, SC_IMG_CACHE, SC_SNAPSHOT, SC_SANITIZE_S = range(5)
SC_N = 5
(AG_REQUESTS, AG_COLD, AG_WARM, AG_LAUNCHED, AG_PROMOTIONS, AG_DEMOTIONS,
 AG_LAT_SUM, AG_QWAIT_SUM, AG_EXEC_GB_S, AG_IDLE_WARM, AG_IDLE_PAUSED,
 AG_IDLE_SNAP) = range(12)
AG_N = 12

# WarmthTier ordinals as floats (DEAD < IMG_CACHED < SNAPSHOT_READY <
# PAUSED < WARM_IDLE, matching repro.core.lifecycle.WarmthTier)
T_DEAD, T_IMG, T_SNAP, T_PAUSED, T_WARM = 0.0, 1.0, 2.0, 3.0, 4.0
N_TIERS = 5
BIG_TIME = 1e30               # "never" deadline (inf-like, finite for f32)


def _tier_select(table, tier):
    """``table[f, tier[f]]`` via one-hot over the small tier axis."""
    cols = table.shape[1]
    out = jnp.zeros(table.shape[0], jnp.float32)
    for t in range(cols):
        out = out + table[:, t] * (tier == t)
    return out


def cluster_step_full(nw, fs, free, arrivals, conc, now, fparam, promote,
                      dwell, ntier, frac, scal):
    """One fixed-dt step of the batched cluster cohort model (one cell).

    Semantics per step, in order (mirroring the scalar simulator's
    dispatch; see docs/batchsim.md for the divergences):

      1. expiry walk — cohorts whose demotion deadline passed slide down
         their schedule (up to K edges per step), freeing/charging the
         per-tier footprint; DEAD edges destroy the cohort.
      2. spawn — a container serves one request at a time, so the cohort
         grows to cover this step's peak concurrency: ``conc`` (the
         host-precomputed max number of arrivals inside one exec window,
         exact from event timestamps) or the Little's-law floor
         ``demand * exec_s / dt``, whichever is larger.  New containers
         place first-fit across workers.
      3. serve — queued + new arrivals consume cohort capacity
         (``n * svc`` requests per step); demoted cohorts promote back to
         WARM_IDLE, their requests billed the promote latency and counted
         cold (matching the scalar ledger, where resumes are cold=True);
         leftovers stay queued and accrue wait.
      4. idle accounting — container-seconds not spent serving are billed
         GB-s at the cohort tier's footprint fraction.

    Returns ``(nw, fs, free, agg_delta[AG_N], extras)`` where ``extras``
    is a ``(cold[F], idle_gb[F])`` pair of *per-function* step deltas —
    the reward channels the RL gym (``repro.learn.gym``) consumes before
    they are summed into the cell aggregate.
    """
    f32 = jnp.float32
    F, W = nw.shape
    K = dwell.shape[1]
    dt = scal[SC_DT]
    dt_eff = jnp.clip(scal[SC_HORIZON] - now, 0.0, dt)
    active = dt_eff > 0.0

    tier = fs[:, FS_TIER]
    edge = fs[:, FS_EDGE]
    deadline = fs[:, FS_DEADLINE]
    queued = fs[:, FS_QUEUED]
    has_snap = fs[:, FS_HAS_SNAP]
    img = fs[:, FS_IMG]
    mem = fparam[:, FP_MEM_MB]
    exec_s = fparam[:, FP_EXEC_S]
    exec_gb = fparam[:, FP_EXEC_GB]
    svc = fparam[:, FP_SVC]
    mem_gb = fparam[:, FP_MEM_GB]
    agg = jnp.zeros((AG_N,), f32)

    # ---- 1. expiry walk (K unrolled edges; a zero dwell can cascade) ---- #
    for _ in range(K):
        n = nw.sum(axis=1)
        edge_c = jnp.clip(edge, 0, K - 1)
        tgt = _tier_select(ntier, edge_c)
        fire = (n > 0) & (deadline <= now) & active
        died = fire & (tgt == T_DEAD)
        demoted = fire & ~died
        old_res = mem * _tier_select(jnp.tile(frac[None, :], (F, 1)), tier)
        new_res = jnp.where(died, 0.0,
                            mem * _tier_select(jnp.tile(frac[None, :],
                                                        (F, 1)), tgt))
        delta_mb = jnp.where(fire, new_res - old_res, 0.0)
        free = free - (nw * delta_mb[:, None]).sum(axis=0)
        agg = agg.at[AG_DEMOTIONS].add((demoted * n).sum())
        nw = jnp.where(died[:, None], 0.0, nw)
        next_edge = jnp.clip(edge + 1, 0, K - 1)
        nxt_dwell = _tier_select(dwell, next_edge)
        deadline = jnp.where(demoted, now + nxt_dwell,
                             jnp.where(died, BIG_TIME, deadline))
        tier = jnp.where(demoted, tgt, tier)
        has_snap = jnp.maximum(has_snap, (demoted & (tgt == T_SNAP)))
        edge = jnp.where(fire, edge + 1.0, edge)

    # ---- 2. spawn to cover within-step concurrency ---- #
    # a container serves requests sequentially, so ``demand`` requests of
    # ``exec_s`` each need ~demand*exec_s/dt concurrent containers
    # (Little's law over the step) — the scalar sim spawns one container
    # per overlapping request; this is its fixed-dt analogue
    demand = queued + arrivals
    n = nw.sum(axis=1)
    required = jnp.maximum(
        jnp.ceil(demand * exec_s / jnp.maximum(dt_eff, 1e-9)), conc)
    spawn_want = jnp.clip(required - n, 0.0, demand)
    spawn_tier = jnp.where(
        has_snap > 0, T_SNAP,
        jnp.where((scal[SC_IMG_CACHE] > 0) & (img > 0), T_IMG, T_DEAD))
    spawn_cost = _tier_select(promote, spawn_tier)

    # vectorized first-fit: every function packs against the CURRENT free
    # vector in parallel (exact whenever one function spawns per step —
    # the dominant case); if simultaneous spawners over-commit a worker,
    # their takes scale back proportionally so free never goes negative
    need = (spawn_want * active.astype(f32))[:, None]            # (F, 1)
    cap_w = jnp.maximum(jnp.floor(free[None, :]
                                  / jnp.maximum(mem, 1.0)[:, None]), 0.0)
    prior = jnp.cumsum(cap_w, axis=1) - cap_w
    take = jnp.clip(need - prior, 0.0, cap_w)                    # (F, W)
    used_w = (take * mem[:, None]).sum(axis=0)
    scale = jnp.where(used_w > free,
                      free / jnp.maximum(used_w, 1e-9), 1.0)
    take = take * scale[None, :]
    nw_pre = nw                       # resident counts before this spawn
    free = free - (take * mem[:, None]).sum(axis=0)
    nw = nw + take
    granted = take.sum(axis=1)
    has_snap = jnp.maximum(has_snap, (granted > 0) * scal[SC_SNAPSHOT])
    img = jnp.maximum(img, (granted > 0).astype(f32))

    # ---- 3. serve queued + fresh demand ---- #
    capacity = jnp.floor((n + granted) * svc
                         * jnp.where(dt > 0, dt_eff / dt, 0.0))
    served = jnp.minimum(demand, capacity)
    cohort_demoted = (tier < T_WARM) & (n > 0)
    # only as many containers promote as the step's concurrency needs;
    # the scalar leaves the rest at the demoted tier on their stale
    # deadlines (SPES-style short dwells then kill them before the next
    # burst), so the surplus retires here rather than re-arming
    used = jnp.clip(
        jnp.maximum(jnp.ceil(served * exec_s / jnp.maximum(dt_eff, 1e-9)),
                    conc), 1.0, jnp.maximum(n, 1.0))
    promoted_req = jnp.where(cohort_demoted, jnp.minimum(served, used), 0.0)
    cold_spawn = jnp.minimum(granted, served - promoted_req)
    warm_served = served - promoted_req - cold_spawn
    prom_cost = _tier_select(promote, tier)
    restore = cohort_demoted & (served > 0)
    res_now = mem * _tier_select(jnp.tile(frac[None, :], (F, 1)), tier)
    # serving re-arms the shared cohort deadline, which the per-container
    # scalar sim does only for the container that served: its surplus
    # siblings keep their own TTL clocks and die ~one warm dwell after
    # their last personal use.  Mimic that with an exponential retirement
    # of the surplus (n - used) at rate dt/warm_dwell whenever a warm
    # cohort serves
    d0 = dwell[:, 0]
    decaying = (~cohort_demoted) & (served > 0) & (n > 0)
    surplus = jnp.clip(n - used, 0.0, None)
    decay = surplus * jnp.minimum(dt_eff / jnp.maximum(d0, 1e-9), 1.0)
    keep = jnp.where(
        restore & (n > 0), used / jnp.maximum(n, 1.0),
        jnp.where(decaying, 1.0 - decay / jnp.maximum(n, 1.0), 1.0))
    # promoted part re-inflates to full memory, surplus frees its
    # demoted footprint (spawns were already charged at placement)
    delta = jnp.where(restore, keep * (mem - res_now), 0.0) \
        - (1.0 - keep) * res_now
    free = free - (nw_pre * delta[:, None]).sum(axis=0)
    nw = nw - nw_pre * (1.0 - keep)[:, None]
    tier = jnp.where(restore, T_WARM, tier)
    agg = agg.at[AG_PROMOTIONS].add(promoted_req.sum())

    leftover = demand - served
    cold = promoted_req + cold_spawn
    sanitize = scal[SC_SANITIZE_S]
    agg = agg.at[AG_REQUESTS].add(served.sum())
    agg = agg.at[AG_COLD].add(cold.sum())
    agg = agg.at[AG_WARM].add(warm_served.sum())
    agg = agg.at[AG_LAUNCHED].add(granted.sum())
    agg = agg.at[AG_LAT_SUM].add(
        (warm_served * (exec_s + sanitize)
         + promoted_req * (prom_cost + exec_s)
         + cold_spawn * (spawn_cost + exec_s)).sum())
    agg = agg.at[AG_QWAIT_SUM].add(leftover.sum() * dt_eff)
    agg = agg.at[AG_LAT_SUM].add(leftover.sum() * dt_eff)
    agg = agg.at[AG_EXEC_GB_S].add(
        ((warm_served * (exec_s + sanitize)
          + (promoted_req + cold_spawn) * exec_s) * exec_gb).sum())

    # any activity re-arms the cohort at the top of its schedule
    active_f = (served + granted) > 0
    edge = jnp.where(active_f, 0.0, edge)
    deadline = jnp.where(active_f, now + exec_s + d0, deadline)
    tier = jnp.where(active_f, T_WARM, tier)
    queued = leftover

    # ---- 4. idle GB-s at the cohort's tier footprint ---- #
    n = nw.sum(axis=1)
    nonidle_s = (warm_served * (exec_s + sanitize)
                 + promoted_req * (exec_s + prom_cost)
                 + cold_spawn * (exec_s + spawn_cost))
    idle_cs = jnp.clip(n * dt_eff - nonidle_s, 0.0, None)
    fr = _tier_select(jnp.tile(frac[None, :], (F, 1)), tier)
    idle_gb = idle_cs * mem_gb * fr
    agg = agg.at[AG_IDLE_WARM].add((idle_gb * (tier == T_WARM)).sum())
    agg = agg.at[AG_IDLE_PAUSED].add((idle_gb * (tier == T_PAUSED)).sum())
    agg = agg.at[AG_IDLE_SNAP].add((idle_gb * (tier == T_SNAP)).sum())

    fs = jnp.stack([tier, edge, deadline, queued, has_snap,
                    img.astype(f32)], axis=1)
    return nw, fs, free, agg, (cold, idle_gb)


def cluster_step_ref(nw, fs, free, arrivals, conc, now, fparam, promote,
                     dwell, ntier, frac, scal):
    """Aggregate-only view of :func:`cluster_step_full` — the signature the
    batch driver and the Pallas twin are parity-tested against."""
    nw, fs, free, agg, _ = cluster_step_full(
        nw, fs, free, arrivals, conc, now, fparam, promote, dwell, ntier,
        frac, scal)
    return nw, fs, free, agg


def ssm_scan_ref(u, delta, A, B, C, D, h0):
    """Mamba-1 selective-scan oracle (sequential over time, fp32 state).

    u, delta: (Batch, T, Din); A: (Din, N); B, C: (Batch, T, N); D: (Din,);
    h0: (Batch, Din, N).  Returns (y (Batch, T, Din), hT).
    Discretisation: h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * u_t.
    """
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs           # (Bt, Din), (Bt, Din), (Bt, N), (Bt, N)
        decay = jnp.exp(d_t[..., None] * Af[None])          # (Bt, Din, N)
        h = decay * h + (d_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(uf, 1, 0), jnp.moveaxis(df, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + uf * D.astype(jnp.float32)[None, None]
    return y.astype(u.dtype), hT
