"""TPU Pallas kernels + jnp reference paths (see ops.py)."""
