"""Flash-decode Pallas kernel: one new token vs a long KV cache (serving
hot spot — the ``decode_32k`` / ``long_500k`` shapes).

TPU adaptation: the cache is streamed HBM→VMEM in (block_s, head_dim) tiles
along the innermost (sequential) grid dimension, with the online-softmax
state for the whole q-head *group* carried in VMEM scratch.  One grid step
processes all ``G = Hq/Hkv`` query heads of a kv head against one KV tile, so
each cache byte is read exactly once per group — the TPU analogue of
flash-decode's split-K, without the CUDA-style cross-SM reduction (the
sequential grid *is* the reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_S = 512


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, num_blocks: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale        # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                # (bs, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    valid = mask_ref[0, :]                                   # (bs,)

    s = q @ k.T                                              # (G, bs)
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(si == num_blocks - 1)
    def _finish():
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, valid_mask, *,
                            block_s: int = DEFAULT_BLOCK_S,
                            interpret: bool = True):
    """q: (B, Hq, D); caches (B, S, Hkv, D); valid_mask (B, S) -> (B, Hq, D)."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    bs = min(block_s, s)
    assert s % bs == 0, "cache length must be a multiple of block_s"
    nb = s // bs
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_decode_kernel, num_blocks=nb,
                               scale=1.0 / (d ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, h, si: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si: (bi, si, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si: (bi, si, h, 0)),
            pl.BlockSpec((1, bs), lambda bi, h, si: (bi, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, h, si: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid_mask)
    return out.reshape(b, hq, d)
